"""End-to-end driver: noise-aware QAT training of a ViT with the paper's SAC
policy, then CIM-simulated inference — the paper's CIFAR-10 experiment on the
procedural stand-in task.

  PYTHONPATH=src python examples/train_vit_cim.py [--steps 200] [--full]

--full uses the paper's exact ViT-small (12L, d=384); default is a reduced
config that trains in a few minutes on CPU.
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import CIMModelConfig
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, image_batch
from repro.models.layers import Ctx
from repro.models.model import build
from repro.models.vit import vit_accuracy, vit_loss
from repro.training import optimizer as opt_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config("vit-small-cifar")
    if not args.full:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=192, d_ff=384,
                                  n_heads=4, n_kv_heads=4, head_dim=48)
    cfg = dataclasses.replace(cfg, cim=CIMModelConfig(mode="qat",
                                                      policy="paper_sac"))
    api = build(cfg)
    params, _ = api.init(jax.random.PRNGKey(0))
    opt_cfg = opt_mod.OptConfig(lr=1.5e-3, warmup_steps=args.steps // 10,
                                total_steps=args.steps, weight_decay=0.01)
    opt = opt_mod.init_opt_state(params)
    dcfg = DataConfig(seed=5, global_batch=args.batch)

    @jax.jit
    def step(params, opt, images, labels, key):
        loss, g = jax.value_and_grad(
            lambda p: vit_loss(p, images, labels, cfg, Ctx.make(cfg, key)))(params)
        params, opt, info = opt_mod.apply_updates(params, g, opt, opt_cfg)
        return params, opt, loss

    t0 = time.time()
    for s in range(args.steps):
        x, y = image_batch(dcfg, s)
        params, opt, loss = step(params, opt, jnp.asarray(x), jnp.asarray(y),
                                 jax.random.fold_in(jax.random.PRNGKey(1), s))
        if s % 25 == 0:
            print(f"step {s:4d}  loss {float(loss):.4f}  "
                  f"({(time.time()-t0)/(s+1)*1e3:.0f} ms/step)")

    # evaluate: ideal digital vs CIM-simulated (SAC policy)
    def eval_acc(mode):
        accs = []
        for s in range(6):
            x, y = image_batch(dcfg, 5000 + s, split="eval")
            ctx = Ctx.make(cfg, jax.random.fold_in(jax.random.PRNGKey(9), s),
                           mode=mode)
            accs.append(float(vit_accuracy(params, jnp.asarray(x),
                                           jnp.asarray(y), cfg, ctx)))
        return sum(accs) / len(accs)

    ideal = eval_acc("off")
    cim = eval_acc("sim")
    print(f"\nideal (digital) accuracy : {ideal:.3%}   (paper: 96.8%)")
    print(f"CIM-sim (SAC)  accuracy  : {cim:.3%}   (paper: 95.8%)")
    print(f"accuracy cost of analog  : {(ideal - cim) * 100:.1f} pt "
          f"(paper: 1.0 pt)")


if __name__ == "__main__":
    main()
