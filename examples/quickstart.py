"""Quickstart: run a linear layer on the CR-CIM macro model and measure the
paper's headline metrics.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (CIMSpec, calibrated_model, cim_dense, paper_sac,
                        sac_efficiency)
from repro.core.metrics import measure_csnr_db, measure_sqnr_db

# --- 1. a linear layer, three execution modes --------------------------------
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (8, 1024))
w = jax.random.normal(jax.random.fold_in(key, 1), (1024, 64))

spec = CIMSpec()                       # 6b/6b, CB on (MLP operating point)
y_ideal = cim_dense(x, w, None, None, mode="digital")
y_qat = cim_dense(x, w, spec, None, mode="qat")       # training: STE fake-quant
y_cim = cim_dense(x, w, spec, jax.random.fold_in(key, 2), mode="sim")

rel = jnp.linalg.norm(y_cim - y_ideal) / jnp.linalg.norm(y_ideal)
print(f"CIM vs ideal rel. error, gaussian drive, total (incl. static DNL/INL):"
      f" {float(rel):.1%}")
print("  (static errors are fixed-pattern and partly absorbed by QAT; the")
print("   network-level cost is ~1 accuracy point — see vit_accuracy bench)")

# at the *peak* drive the paper's CSNR characterises (full-range operands):
from repro.core import quant
from repro.core.cim import cim_matmul_bit_exact
xq = jax.random.randint(key, (8, 1024), -31, 32)
wq = jax.random.randint(jax.random.fold_in(key, 1), (1024, 64), -31, 32)
y_bit = cim_matmul_bit_exact(xq, wq, jax.random.fold_in(key, 3), spec)
rel_peak = jnp.linalg.norm(y_bit - (xq @ wq)) / jnp.linalg.norm((xq @ wq))
print(f"CIM vs ideal rel. error, peak drive (bit-exact SAR chain): "
      f"{float(rel_peak):.1%}")

# --- 2. the macro's accuracy metrics -----------------------------------------
print(f"SQNR  (paper 45.3 dB): {measure_sqnr_db(spec):5.1f} dB")
print(f"CSNR  (paper 31.3 dB): {measure_csnr_db(spec, m=24, n=8, reps=6):5.1f} dB")

# --- 3. the SAC policy + energy model ----------------------------------------
pol = paper_sac()
print(f"attention linears -> {pol.attn.in_bits}b wo/CB, "
      f"MLP linears -> {pol.mlp.in_bits}b w/CB")
em = calibrated_model()
print(f"peak efficiency (paper 818): "
      f"{em.tops_per_watt(CIMSpec(cb=False)) / 1e12:.0f} TOPS/W (1b-norm)")
print(f"SAC transformer efficiency gain (paper 2.1x): {sac_efficiency(em):.2f}x")
